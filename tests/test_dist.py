"""Multi-device distribution tests.

These run in SUBPROCESSES with XLA_FLAGS=--xla_force_host_platform_device_count=8
because the main pytest process must keep the default single CPU device
(per the dry-run isolation requirement).  Each subprocess script asserts and
exits nonzero on failure.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.dist

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_script(body: str, n_dev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", body], capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, f"subprocess failed:\nSTDOUT:{r.stdout}\nSTDERR:{r.stderr[-3000:]}"
    return r.stdout


PREAMBLE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
"""


class TestExpertParallel:
    def test_ep_matches_dense(self):
        run_script(PREAMBLE + """
from repro.configs.base import ModelConfig, FFNSpec
from repro.core.moe import init_moe, moe_layer
from repro.parallel.sharding import use_mesh

cfg = ModelConfig(name="t", family="moe", source="x", d_model=64, num_heads=4, num_kv_heads=2,
                  head_dim=16, vocab_size=100, segments=(), param_dtype="float32", compute_dtype="float32")
spec = FFNSpec(kind="moe", d_ff=128, num_experts=8, top_k=2, capacity_factor=8.0, residual=True)
p = init_moe(jax.random.PRNGKey(0), cfg, spec, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))
y_ref, _ = moe_layer(cfg, spec, p, x, impl="dense")
with use_mesh(mesh):
    y_ep, _ = jax.jit(lambda p, x: moe_layer(cfg, spec, p, x, impl="ep"))(p, x)
np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep), atol=1e-4)

def loss(p, x, impl):
    y, a = moe_layer(cfg, spec, p, x, impl=impl)
    return jnp.sum(y**2) + 0.01*a
g_ref = jax.grad(loss)(p, x, "dense")
with use_mesh(mesh):
    g_ep = jax.jit(jax.grad(lambda p, x: loss(p, x, "ep")))(p, x)
jax.tree.map(lambda a,b: np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4), g_ref, g_ep)
print("EP OK")
""")

    def test_coordinated_a2a_group_size(self):
        """The §5.3 claim: a2a groups span only the EP axis (p/L), not p."""
        run_script(PREAMBLE + """
from repro.configs.base import ModelConfig, FFNSpec
from repro.core.moe import init_moe, moe_layer
from repro.parallel.sharding import use_mesh
import re

cfg = ModelConfig(name="t", family="moe", source="x", d_model=64, num_heads=4, num_kv_heads=2,
                  head_dim=16, vocab_size=100, segments=(), param_dtype="float32", compute_dtype="float32")
spec = FFNSpec(kind="moe", d_ff=128, num_experts=8, top_k=1, capacity_factor=4.0)
p = init_moe(jax.random.PRNGKey(0), cfg, spec, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))
with use_mesh(mesh):
    txt = jax.jit(lambda p, x: moe_layer(cfg, spec, p, x, impl="ep")).lower(p, x).compile().as_text()
groups = []
for m in re.finditer(r'all-to-all[^\\n]*replica_groups=\\{\\{([^}]*)\\}', txt):
    groups.append(len(m.group(1).split(",")))
for m in re.finditer(r'all-to-all[^\\n]*replica_groups=\\[(\\d+),(\\d+)\\]', txt):
    groups.append(int(m.group(2)))
assert groups, "no all-to-all found in HLO"
assert all(g == 4 for g in groups), f"a2a groups {groups} != data-axis size 4 (coordinated a2a)"
print("coordinated a2a OK", groups)
""")


class TestHierarchicalA2A:
    def test_equals_flat_and_roundtrips(self):
        run_script("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.collectives import (flat_all_to_all, flat_all_to_all_back,
    hierarchical_all_to_all, hierarchical_all_to_all_back)
from repro.parallel.compat import make_mesh, shard_map
mesh = make_mesh((2, 4), ("pod", "data"))
E, C, D = 16, 4, 8
xg = jax.random.normal(jax.random.PRNGKey(0), (8, E, C, D))
def run(fn):
    def body(xs):
        return fn(xs.reshape(E, C, D))[None]
    return shard_map(body, mesh=mesh, in_specs=P(("pod","data"), None, None, None),
                     out_specs=P(("pod","data"), None, None, None))(xg)
flat = run(lambda x: flat_all_to_all(x, ("pod","data")))
hier = run(lambda x: hierarchical_all_to_all(x, "data", "pod"))
np.testing.assert_allclose(np.asarray(flat), np.asarray(hier), atol=0)
rt = run(lambda x: hierarchical_all_to_all_back(hierarchical_all_to_all(x, "data", "pod"), "data", "pod"))
np.testing.assert_allclose(np.asarray(rt), np.asarray(xg), atol=0)
print("hierarchical a2a OK")
""")


class TestShardedTrainStep:
    def test_train_step_on_mesh_matches_single_device(self):
        run_script(PREAMBLE + """
from repro.configs.registry import all_configs, make_reduced
from repro.models.model import init_params
from repro.training.optimizer import init_adamw
from repro.training.trainer import TrainConfig, make_train_step
from repro.parallel.sharding import use_mesh
from repro.parallel.params import param_pspecs, batch_pspec
from jax.sharding import NamedSharding

cfg = make_reduced(all_configs()["llama4-maverick-400b-a17b"])
params = init_params(cfg, jax.random.PRNGKey(0))
opt = init_adamw(params)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab_size)
step = make_train_step(cfg, TrainConfig(lr=1e-3, warmup_steps=1, decay_steps=10))
p1, o1, m1 = jax.jit(step)(params, opt, toks, toks)

with use_mesh(mesh):
    pspecs = param_pspecs(mesh, params, mode="train")
    shard = lambda t, s: jax.device_put(t, NamedSharding(mesh, s))
    params_s = jax.tree.map(shard, params, pspecs)
    opt_s = init_adamw(params_s)
    toks_s = jax.device_put(toks, NamedSharding(mesh, batch_pspec(mesh, 2)))
    # fresh wrapper: jax caches traces per function object, and the first
    # jax.jit(step) traced WITHOUT the mesh (dense-dispatch fallback baked
    # in); the mesh run must retrace so moe_impl='ep' sees the active mesh
    p2, o2, m2 = jax.jit(lambda *a: step(*a))(params_s, opt_s, toks_s, toks_s)
assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (float(m1["loss"]), float(m2["loss"]))
jax.tree.map(lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4),
             p1, p2)
print("sharded train step OK")
""")

    def test_decode_on_mesh_matches_single_device(self):
        run_script(PREAMBLE + """
from repro.configs.registry import all_configs, make_reduced
from repro.models.model import init_params, init_caches, prefill, decode_step
from repro.parallel.sharding import use_mesh
from repro.parallel.params import param_pspecs, cache_pspecs, batch_pspec
from jax.sharding import NamedSharding

cfg = make_reduced(all_configs()["gemma3-27b"])
params = init_params(cfg, jax.random.PRNGKey(0))
B, S = 8, 12
toks = jax.random.randint(jax.random.PRNGKey(1), (B, S+1), 0, cfg.vocab_size)
caches = init_caches(cfg, B, capacity=S+2)
lg1, c1 = jax.jit(lambda p,t,c: prefill(cfg,p,t,c))(params, toks[:, :S], caches)
lg1d, _ = jax.jit(lambda p,t,i,c: decode_step(cfg,p,t,i,c))(params, toks[:, S:S+1], jnp.asarray(S, jnp.int32), c1)

with use_mesh(mesh):
    shard = lambda t, s: jax.device_put(t, NamedSharding(mesh, s))
    params_s = jax.tree.map(shard, params, param_pspecs(mesh, params))
    caches_s = jax.tree.map(shard, caches, cache_pspecs(mesh, caches, B))
    toks_s = jax.device_put(toks, NamedSharding(mesh, batch_pspec(mesh, 2)))
    lg2, c2 = jax.jit(lambda p,t,c: prefill(cfg,p,t,c))(params_s, toks_s[:, :S], caches_s)
    lg2d, _ = jax.jit(lambda p,t,i,c: decode_step(cfg,p,t,i,c))(params_s, toks_s[:, S:S+1], jnp.asarray(S, jnp.int32), c2)
np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), atol=2e-4)
np.testing.assert_allclose(np.asarray(lg1d), np.asarray(lg2d), atol=2e-4)
print("sharded decode OK")
""")


class TestAllGatherEPSchedule:
    def test_decode_regime_matches_dense(self):
        """Small-batch (decode) EP schedule: all-gather tokens -> local
        experts -> psum_scatter (EXPERIMENTS.md §Perf P3 iteration 1)."""
        run_script(PREAMBLE + """
from repro.configs.base import ModelConfig, FFNSpec
from repro.core.moe import init_moe, moe_layer
from repro.parallel.sharding import use_mesh

cfg = ModelConfig(name="t", family="moe", source="x", d_model=64, num_heads=4, num_kv_heads=2,
                  head_dim=16, vocab_size=100, segments=(), param_dtype="float32", compute_dtype="float32")
spec = FFNSpec(kind="moe", d_ff=128, num_experts=8, top_k=2, capacity_factor=8.0, residual=True)
p = init_moe(jax.random.PRNGKey(0), cfg, spec, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 64))  # 1 token/shard -> allgather path
y_ref, a_ref = moe_layer(cfg, spec, p, x, impl="dense")
with use_mesh(mesh):
    y_ep, a_ep = jax.jit(lambda p, x: moe_layer(cfg, spec, p, x, impl="ep"))(p, x)
np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep), atol=1e-4)
assert abs(float(a_ref) - float(a_ep)) < 1e-5
print("allgather EP OK")
""")


class TestContextParallelAttention:
    def test_nondivisible_heads_seq_sharded_matches(self):
        """llama4-style head counts (not divisible by 'model') fall back to
        query-sequence sharding; results must match the unsharded reference
        (EXPERIMENTS.md §Perf P2 iteration 1)."""
        run_script("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.parallel.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
from repro.configs.base import AttnSpec, ModelConfig
from repro.models.attention import attention, init_attention
from repro.parallel.sharding import use_mesh

cfg = ModelConfig(name="t", family="dense", source="x", d_model=64, num_heads=6, num_kv_heads=2,
                  head_dim=16, vocab_size=64, segments=(), param_dtype="float32", compute_dtype="float32")
assert cfg.num_heads % 4 != 0  # triggers the context-parallel fallback
spec = AttnSpec(kind="global")
ap = init_attention(jax.random.PRNGKey(0), cfg, spec, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
pos = jnp.arange(16, dtype=jnp.int32)[None]
y_ref, _ = attention(cfg, spec, ap, x, pos, mode="train")
with use_mesh(mesh):
    y_cp, _ = jax.jit(lambda ap, x: attention(cfg, spec, ap, x, pos, mode="train"))(ap, x)
np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_cp), atol=1e-4)
print("context-parallel attention OK")
""")


class TestCrossPodHierarchicalEP:
    def test_hier_ep_matches_dense(self):
        """Experts sharded over (pod, data) with the paper's Fig. 8
        hierarchical two-stage a2a; values and grads must match the
        single-device dense reference."""
        run_script("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs.base import ModelConfig, FFNSpec
from repro.core.moe import init_moe, moe_layer
from repro.core.moe_parallel import set_ep_pod
from repro.parallel.sharding import use_mesh, RULESETS

cfg = ModelConfig(name="t", family="moe", source="x", d_model=64, num_heads=4, num_kv_heads=2,
                  head_dim=16, vocab_size=100, segments=(), param_dtype="float32", compute_dtype="float32")
from repro.parallel.compat import make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
spec = FFNSpec(kind="moe", d_ff=128, num_experts=8, top_k=2, capacity_factor=8.0)
p = init_moe(jax.random.PRNGKey(0), cfg, spec, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 64))
y_ref, _ = moe_layer(cfg, spec, p, x, impl="dense")
set_ep_pod(True)
with use_mesh(mesh, RULESETS["ep_pod"]):
    y_ep, _ = jax.jit(lambda p, x: moe_layer(cfg, spec, p, x, impl="ep"))(p, x)
np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep), atol=1e-4)

def loss(p, x, impl):
    y, a = moe_layer(cfg, spec, p, x, impl=impl)
    return jnp.sum(y**2) + 0.01*a
g_ref = jax.grad(loss)(p, x, "dense")
with use_mesh(mesh, RULESETS["ep_pod"]):
    g_ep = jax.jit(jax.grad(lambda p, x: loss(p, x, "ep")))(p, x)
jax.tree.map(lambda a,b: np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4), g_ref, g_ep)
print("cross-pod hierarchical EP OK")
""")
