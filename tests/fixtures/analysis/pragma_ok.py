"""Golden fixture: one violation, suppressed by an `# analysis: allow` pragma
with a justification — reported as suppressed, never as active."""
import jax.numpy as jnp
import numpy as np


def stash(x):
    # analysis: allow(host-asarray) — fixture: the one sanctioned sync
    return np.asarray(jnp.tanh(x))
