"""Golden fixture: trips exactly `host-item` (.item() device->host sync)."""
import jax.numpy as jnp


def loss_scalar(x):
    total = jnp.sum(x)
    return total.item()
