"""Golden fixture: trips exactly `block-sync` (explicit device fence)."""
import jax


def fence(x):
    jax.block_until_ready(x)
    return x
