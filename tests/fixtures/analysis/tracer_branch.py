"""Golden fixture: trips exactly `tracer-branch` (Python if on a tracer)."""
import jax.numpy as jnp


def clip_if_large(x, limit):
    if jnp.max(x) > limit:
        return x * 0.5
    return x
