"""Golden fixture: trips exactly `host-cast` (float() over a device value)."""
import jax.numpy as jnp


def mean_as_float(x):
    return float(jnp.mean(x))
