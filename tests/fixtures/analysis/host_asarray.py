"""Golden fixture: trips exactly `host-asarray` (np.asarray of device value)."""
import jax.numpy as jnp
import numpy as np


def to_host(x):
    return np.asarray(jnp.tanh(x))
