"""Golden fixture: trips exactly `debug-call` (stray jax.debug.print)."""
import jax


def log_tick(x):
    jax.debug.print("tick value {}", x)
    return x
