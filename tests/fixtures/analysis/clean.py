"""Golden fixture: trips NO rule — pure device math, static-metadata reads,
host predicates, and comprehensions over tree leaves are all allowed."""
import jax
import jax.numpy as jnp


def normalize(x):
    return x / (jnp.linalg.norm(x) + 1e-6)


def widths(caches):
    return [leaf.shape[-1] for leaf in jax.tree.leaves(caches)]


def on_tpu():
    return jax.default_backend() == "tpu"
