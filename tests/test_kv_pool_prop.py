"""Property-fuzzed KVBlockPool invariants (prefix-sharing / CoW refcounts).

Random alloc/share/fork/drop/release/preempt traces are driven against a
pure-python shadow model; after every operation the pool must satisfy:

  * refcounts are never negative and always equal the holder-set size;
  * no page is simultaneously on the freelist and referenced;
  * accounting is exact — ``free_count + used_count == n_pages`` and the sum
    of per-reference shares ``1/refcount(p)`` over every (page, holder)
    reference equals ``used_count`` exactly (computed in Fractions: each
    physical page's cost is split over its holders and sums back to one);
  * freeing an already-free page always raises, never corrupts the freelist.

Runs under the tests/_hyp.py shim: with hypothesis installed this fuzzes
many seeds, without it the ``seed=0`` trace still runs as a deterministic
smoke test (the trace itself is numpy-seeded, so one example is still ~200
random operations).

Also holds the deterministic regressions for the owner-tag release bug: a
preempted slot releasing a page another slot still references must DECREF
it, not free it — the pre-refcount pool freed shared pages out from under
their sharers.
"""
from fractions import Fraction

import numpy as np
import pytest

from repro.serving.kv_pool import BlockTables, KVBlockPool

from tests._hyp import given, settings, st


# ---------------------------------------------------------------------------
# Deterministic regressions: CoW-aware release / share / fork semantics
# ---------------------------------------------------------------------------


class TestSharedReleaseRegression:
    def test_release_decrefs_shared_page_instead_of_freeing(self):
        """Regression: slot 1 shares slot 0's page; preempting slot 0 must
        NOT free the page — slot 1 still reads it.  The old owner-tagged
        release assumed exclusive ownership and yanked it."""
        pool = KVBlockPool(4, 8)
        (page,) = pool.alloc(1, owner=0)
        pool.share([page], owner=1)
        assert pool.refcount(page) == 2
        freed = pool.release(0)  # slot 0 preempted
        assert freed == []  # decref only — nothing actually freed
        assert pool.refcount(page) == 1
        assert pool.free_count == 3  # page still live for slot 1
        assert pool.owned_by(1) == [page]
        # slot 1's own departure is what frees it
        assert pool.release(1) == [page]
        assert pool.free_count == 4

    def test_release_mixes_exclusive_and_shared(self):
        pool = KVBlockPool(8, 4)
        shared = pool.alloc(2, owner=0)
        private = pool.alloc(3, owner=0)
        pool.share(shared, owner=1)
        freed = pool.release(0)
        # exclusive pages freed, shared pages only decrefed
        assert sorted(freed) == sorted(private)
        assert all(pool.refcount(p) == 1 for p in shared)
        assert pool.free_count == 8 - len(shared)

    def test_free_of_shared_page_raises(self):
        pool = KVBlockPool(4, 8)
        (page,) = pool.alloc(1, owner=0)
        pool.share([page], owner=1)
        with pytest.raises(ValueError, match="still referenced"):
            pool.free([page])
        pool.check()

    def test_share_free_page_or_double_share_raises(self):
        pool = KVBlockPool(4, 8)
        (page,) = pool.alloc(1, owner=0)
        with pytest.raises(ValueError, match="free page"):
            pool.share([2], owner=1)
        with pytest.raises(ValueError, match="already holds"):
            pool.share([page], owner=0)
        pool.check()

    def test_fork_gives_private_page_and_keeps_sharers(self):
        pool = KVBlockPool(4, 8)
        (page,) = pool.alloc(1, owner=0)
        pool.share([page], owner=1)
        new = pool.fork(page, owner=1)
        assert new is not None and new != page
        assert pool.refcount(page) == 1 and 0 in pool._holders[page]
        assert pool.refcount(new) == 1 and pool.owned_by(1) == [new]
        pool.check()

    def test_fork_on_dry_pool_returns_none(self):
        pool = KVBlockPool(2, 8)
        (page,) = pool.alloc(1, owner=0)
        pool.share([page], owner=1)
        pool.alloc(1, owner=2)  # pool now dry
        assert pool.fork(page, owner=1) is None
        assert pool.refcount(page) == 2  # failed fork left the ref intact
        pool.check()

    def test_shared_count_counts_physical_pages_once(self):
        pool = KVBlockPool(6, 4)
        pages = pool.alloc(3, owner=0)
        assert pool.shared_count == 0
        pool.share(pages[:2], owner=1)
        pool.share(pages[:1], owner=2)
        assert pool.shared_count == 2
        assert pool.used_count == 3  # occupancy counts shared pages once


class TestBlockTableSharing:
    def test_copy_row_and_set_entry(self):
        bt = BlockTables(2, 4)
        bt.append(0, [5, 3, 7])
        bt.copy_row(1, 0)
        assert list(bt.row(1)[:3]) == [5, 3, 7]
        bt.set_entry(1, 2, 9)  # CoW divergence at the boundary page
        assert list(bt.row(0)[:3]) == [5, 3, 7]
        assert list(bt.row(1)[:3]) == [5, 3, 9]
        with pytest.raises(ValueError, match="unmapped"):
            bt.set_entry(1, 3, 2)


# ---------------------------------------------------------------------------
# Property fuzz: random operation traces vs a shadow model
# ---------------------------------------------------------------------------


class _Shadow:
    """Reference model: page -> set of holders."""

    def __init__(self, n_pages):
        self.n_pages = n_pages
        self.holders = {}  # page -> set(owners); absent = free

    @property
    def free(self):
        return [p for p in range(self.n_pages) if p not in self.holders]

    def live_for(self, owner):
        return [p for p, h in self.holders.items() if owner in h]


def _assert_matches(pool: KVBlockPool, shadow: _Shadow):
    pool.check()
    assert pool.free_count == len(shadow.free)
    assert pool.used_count == shadow.n_pages - len(shadow.free)
    assert pool.free_count + pool.used_count == pool.n_pages
    # exact share accounting: every (page, holder) reference costs 1/refs of
    # a page; the fractions must sum back to the physical page count
    total = Fraction(0)
    for p, holders in shadow.holders.items():
        assert pool.refcount(p) == len(holders) >= 1
        for _ in holders:
            total += Fraction(1, len(holders))
    assert total == pool.used_count
    assert pool.shared_count == sum(1 for h in shadow.holders.values() if len(h) > 1)
    for owner in range(8):
        assert sorted(pool.owned_by(owner)) == sorted(shadow.live_for(owner))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_pool_random_trace_invariants(seed):
    rng = np.random.default_rng(seed)
    n_pages = int(rng.integers(2, 12))
    pool = KVBlockPool(n_pages, page_size=int(rng.integers(1, 16)))
    shadow = _Shadow(n_pages)
    owners = list(range(int(rng.integers(2, 8))))

    for _ in range(200):
        op = rng.choice(["alloc", "share", "fork", "drop", "release", "free",
                         "double_free"])
        owner = int(rng.choice(owners))
        if op == "alloc":
            n = int(rng.integers(0, n_pages + 2))
            got = pool.alloc(n, owner)
            if n > len(shadow.free):
                assert got is None, "alloc must be all-or-nothing"
            else:
                assert got is not None and len(got) == n
                assert len(set(got)) == n
                for p in got:
                    assert p not in shadow.holders
                    shadow.holders[p] = {owner}
        elif op == "share":
            candidates = [p for p, h in shadow.holders.items() if owner not in h]
            if candidates:
                k = int(rng.integers(1, len(candidates) + 1))
                pages = list(rng.choice(candidates, size=k, replace=False))
                pool.share(pages, owner)
                for p in pages:
                    shadow.holders[int(p)].add(owner)
        elif op == "fork":
            held = shadow.live_for(owner)
            if held:
                p = int(rng.choice(held))
                new = pool.fork(p, owner)
                if not shadow.free:
                    assert new is None, "fork with a dry pool must refuse"
                else:
                    assert new is not None and new in shadow.free
                    shadow.holders[new] = {owner}
                    shadow.holders[p].discard(owner)
                    if not shadow.holders[p]:
                        del shadow.holders[p]
        elif op == "drop":
            held = shadow.live_for(owner)
            if held:
                p = int(rng.choice(held))
                was_last = len(shadow.holders[p]) == 1
                assert pool.drop(p, owner) == was_last
                shadow.holders[p].discard(owner)
                if not shadow.holders[p]:
                    del shadow.holders[p]
        elif op == "release":  # completion / preemption of a whole slot
            held = set(shadow.live_for(owner))
            expect_freed = {p for p in held if len(shadow.holders[p]) == 1}
            freed = pool.release(owner)
            assert set(freed) == expect_freed, "release must free only refs==1 pages"
            for p in held:
                shadow.holders[p].discard(owner)
                if not shadow.holders[p]:
                    del shadow.holders[p]
        elif op == "free":
            exclusive = [p for p, h in shadow.holders.items() if len(h) == 1]
            if exclusive:
                p = int(rng.choice(exclusive))
                pool.free([p])
                del shadow.holders[p]
        elif op == "double_free":
            if shadow.free:
                p = int(rng.choice(shadow.free))
                with pytest.raises(ValueError, match="double free"):
                    pool.free([p])
                with pytest.raises(ValueError, match="double free"):
                    pool.drop(p, owner)
        _assert_matches(pool, shadow)

    # drain: releasing every owner empties the pool completely
    for owner in owners:
        pool.release(owner)
        shadow_holders = dict(shadow.holders)
        for p, h in shadow_holders.items():
            h.discard(owner)
            if not h:
                del shadow.holders[p]
    assert pool.free_count == n_pages
    _assert_matches(pool, shadow)


# ---------------------------------------------------------------------------
# Speculative-window run helpers: commit by refcount handoff, rollback by
# dropping private forks (serving/spec.py's page lifecycle)
# ---------------------------------------------------------------------------


class TestSpecRunHelpers:
    def test_commit_fork_run_hands_off_shared_base(self):
        """The normal spec commit: the boundary base stays live for its
        sharer, the fork (already owned) replaces it — the owner's page
        count is conserved and nothing is freed."""
        pool = KVBlockPool(4, 4)
        (base,) = pool.alloc(1, owner=0)
        pool.share([base], owner=1)  # prefix sharer
        (fork,) = pool.alloc(1, owner=0)
        assert pool.commit_fork_run([base], owner=0) == []
        assert pool.refcount(base) == 1  # sharer keeps it
        assert sorted(pool.owned_by(0)) == [fork]
        pool.check()

    def test_commit_fork_run_frees_base_when_sharer_departed(self):
        """A sharer preempted mid-speculation leaves the committing owner as
        the last holder: commit must FREE the base (and report it, so the
        engine device-resets + prefix-evicts it)."""
        pool = KVBlockPool(4, 4)
        (base,) = pool.alloc(1, owner=0)
        pool.share([base], owner=1)
        (fork,) = pool.alloc(1, owner=0)
        pool.release(1)  # sharer departs between fork and commit
        assert pool.commit_fork_run([base], owner=0) == [base]
        assert base in [p for p in range(4) if p not in
                        {q for q in pool.owned_by(0)}]
        pool.check()

    def test_drop_fork_run_frees_private_forks(self):
        pool = KVBlockPool(6, 4)
        forks = pool.alloc(3, owner=2)
        assert sorted(pool.drop_fork_run(forks, owner=2)) == sorted(forks)
        assert pool.free_count == 6
        pool.check()

    def test_drop_fork_run_refuses_shared_page(self):
        """A rollback page with refcount > 1 means the scheduler leaked it
        into a table/prefix index — freeing it would corrupt the sharer, so
        the run must refuse atomically (no partial drops)."""
        pool = KVBlockPool(6, 4)
        private = pool.alloc(1, owner=0)
        (shared,) = pool.alloc(1, owner=0)
        pool.share([shared], owner=1)
        with pytest.raises(ValueError, match="not a private fork"):
            pool.drop_fork_run(private + [shared], owner=0)
        # atomic refusal: the valid private page was NOT dropped
        assert sorted(pool.owned_by(0)) == sorted(private + [shared])
        pool.check()

    def test_drop_fork_run_refuses_foreign_page(self):
        pool = KVBlockPool(6, 4)
        (theirs,) = pool.alloc(1, owner=1)
        with pytest.raises(ValueError, match="not a private fork"):
            pool.drop_fork_run([theirs], owner=0)
        assert pool.refcount(theirs) == 1
        pool.check()


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_spec_window_trace_invariants(seed):
    """Random speculative-window lifecycles vs the shadow model: each owner
    cycles plan (alloc fresh pages + fork a shared boundary) -> verify ->
    commit a random prefix of the window (refcount handoff for the
    boundary, keep the accepted fresh pages) + roll back the rest, with
    random mid-speculation preemptions (release while a window is open)
    interleaved.  After 200+ ops and a final drain the pool must be empty
    with exact refcounts throughout."""
    rng = np.random.default_rng(seed)
    n_pages = int(rng.integers(6, 16))
    pool = KVBlockPool(n_pages, page_size=4)
    shadow = _Shadow(n_pages)
    owners = list(range(4))
    windows = {}  # owner -> {"fresh": [...], "fork": page|None, "base": page|None}

    def _close(owner, accept_n):
        """Commit accept_n of the window's fresh pages, roll back the rest,
        hand off the boundary fork (if any)."""
        w = windows.pop(owner)
        if w["base"] is not None:
            was_last = len(shadow.holders[w["base"]]) == 1
            freed = pool.commit_fork_run([w["base"]], owner)
            assert freed == ([w["base"]] if was_last else [])
            shadow.holders[w["base"]].discard(owner)
            if not shadow.holders[w["base"]]:
                del shadow.holders[w["base"]]
        reject = w["fresh"][accept_n:]
        if reject:
            assert sorted(pool.drop_fork_run(reject, owner)) == sorted(reject)
            for p in reject:
                del shadow.holders[p]

    for step in range(220):
        op = rng.choice(["plan", "commit", "preempt", "share"])
        owner = int(rng.choice(owners))
        if op == "plan" and owner not in windows:
            k = int(rng.integers(1, 4))
            # fork a boundary only when this owner shares a page
            shared = [p for p, h in shadow.holders.items()
                      if owner in h and len(h) > 1]
            base = int(rng.choice(shared)) if shared and rng.integers(2) else None
            need = k + (1 if base is not None else 0)
            got = pool.alloc(need, owner)
            if got is None:
                assert need > len(shadow.free)
                continue
            for p in got:
                shadow.holders[p] = {owner}
            fork = got.pop() if base is not None else None
            windows[owner] = {"fresh": got, "fork": fork, "base": base}
        elif op == "commit" and owner in windows:
            _close(owner, int(rng.integers(0, len(windows[owner]["fresh"]) + 1)))
        elif op == "preempt":
            # release mid-speculation: the open window's pages are the
            # owner's refs==1 pages, freed with everything else it holds
            windows.pop(owner, None)
            held = set(shadow.live_for(owner))
            expect = {p for p in held if len(shadow.holders[p]) == 1}
            assert set(pool.release(owner)) == expect
            for p in held:
                shadow.holders[p].discard(owner)
                if not shadow.holders[p]:
                    del shadow.holders[p]
        elif op == "share":
            # never an open window's pages: the engine only shares COMMITTED
            # prompt pages (prefix index / fork admission), and an in-flight
            # verify window is invisible to other slots by construction
            in_flight = {p for w in windows.values()
                         for p in w["fresh"] + [w["fork"]]}
            mine = [p for p, h in shadow.holders.items()
                    if owner in h and len(h) == 1 and p not in in_flight]
            other = int(rng.choice([o for o in owners if o != owner]))
            if mine:
                p = int(rng.choice(mine))
                pool.share([p], other)
                shadow.holders[p].add(other)
        _assert_matches(pool, shadow)

    for owner in owners:
        windows.pop(owner, None)
        pool.release(owner)
        for p in list(shadow.holders):
            shadow.holders[p].discard(owner)
            if not shadow.holders[p]:
                del shadow.holders[p]
    assert pool.free_count == n_pages, "leaked speculative fork pages"
    _assert_matches(pool, shadow)
