"""Flash-attention Pallas kernel vs pure-jnp oracle (interpret mode), with
hypothesis shape sweeps — the kernel behind the roofline's score-tensor
exclusion (EXPERIMENTS.md §Roofline)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a single-draw fallback shim

from repro.kernels.flash_attention import flash_attention, flash_attention_ref


def _qkv(BH, S, T, dh, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (
        jax.random.normal(ks[0], (BH, S, dh)),
        jax.random.normal(ks[1], (BH, T, dh)),
        jax.random.normal(ks[2], (BH, T, dh)),
    )


class TestFlashAttention:
    @pytest.mark.parametrize(
        "BH,S,T,dh,causal",
        [
            (2, 256, 256, 64, True),
            (1, 512, 512, 32, True),
            (3, 128, 384, 16, False),
            (2, 128, 128, 128, True),
        ],
    )
    def test_matches_ref(self, BH, S, T, dh, causal):
        q, k, v = _qkv(BH, S, T, dh, seed=S + T)
        got = flash_attention(q, k, v, scale=dh**-0.5, causal=causal)
        want = flash_attention_ref(q, k, v, scale=dh**-0.5, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=1e-4)

    def test_block_shape_invariance(self):
        """Online softmax must be exact regardless of the k-tiling."""
        q, k, v = _qkv(1, 256, 512, 32, seed=9)
        outs = [
            flash_attention(q, k, v, scale=0.2, causal=False, block_q=bq, block_k=bk)
            for bq, bk in [(128, 512), (128, 128), (256, 64)]
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=2e-5)

    def test_bf16(self):
        q, k, v = (t.astype(jnp.bfloat16) for t in _qkv(2, 128, 128, 64, seed=4))
        got = flash_attention(q, k, v, scale=0.125, causal=True)
        want = flash_attention_ref(q, k, v, scale=0.125, causal=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), atol=2e-2, rtol=2e-2
        )

    @settings(max_examples=8, deadline=None)
    @given(
        S=st.sampled_from([128, 256]),
        T=st.sampled_from([128, 256, 512]),
        dh=st.sampled_from([16, 64]),
        causal=st.booleans(),
        seed=st.integers(0, 50),
    )
    def test_property_sweep(self, S, T, dh, causal, seed):
        if causal:
            T = S  # kernel's causal mask assumes aligned q/k position ranges
        q, k, v = _qkv(1, S, T, dh, seed=seed)
        got = flash_attention(q, k, v, scale=dh**-0.5, causal=causal)
        want = flash_attention_ref(q, k, v, scale=dh**-0.5, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=1e-3)
