"""Import guard for `hypothesis` so the suite collects everywhere.

When hypothesis is installed (see requirements-dev.txt) this re-exports the
real ``given`` / ``settings`` / ``st``.  When it is missing (the bare
container image), a minimal fallback shim runs each property test exactly
once with a deterministic draw from every strategy (first element of
``sampled_from``, ``min_value`` of ``integers``, ``False`` for
``booleans``) — a single-example smoke test instead of a collection error.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # fallback shim
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, value):
            self.value = value

    class _Strategies:
        @staticmethod
        def sampled_from(seq):
            return _Strategy(seq[0])

        @staticmethod
        def integers(min_value=0, max_value=None):
            return _Strategy(min_value)

        @staticmethod
        def booleans():
            return _Strategy(False)

        @staticmethod
        def floats(min_value=0.0, max_value=None, **_kw):
            return _Strategy(min_value)

    st = _Strategies()

    def given(**strategies):
        draw = {k: s.value for k, s in strategies.items()}

        def deco(fn):
            # NB: no functools.wraps — copying __wrapped__ would make pytest
            # inspect fn's signature and hunt for fixtures named T/E/K/...
            def wrapper(*args, **kwargs):
                return fn(*args, **draw, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def settings(*_a, **_kw):
        return lambda fn: fn
