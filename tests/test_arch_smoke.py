"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each of the 10 assigned architectures runs one forward pass and one train
step on CPU; output shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import count_params
from repro.configs.registry import ASSIGNED, all_configs, make_reduced
from repro.data.pipeline import data_stream
from repro.models.model import encode, forward, init_params
from repro.training.optimizer import init_adamw
from repro.training.trainer import TrainConfig, make_train_step


def _inputs(cfg, B=2, S=16, seed=0):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.family == "encdec":
        src = jax.random.normal(
            jax.random.PRNGKey(seed + 1), (B, cfg.frontend.n_tokens, cfg.frontend.embed_dim)
        )
        kw["memory"] = encode(cfg, init_params(cfg, jax.random.PRNGKey(0)), src)
    if cfg.family == "vlm":
        kw["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 2), (B, cfg.frontend.n_tokens, cfg.frontend.embed_dim)
        )
    return toks, kw


@pytest.mark.parametrize("arch", ASSIGNED)
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, arch):
        cfg = make_reduced(all_configs()[arch])
        params = init_params(cfg, jax.random.PRNGKey(0))
        B, S = 2, 16
        toks, kw = _inputs(cfg, B, S)
        if cfg.family == "encdec":
            kw["memory"] = encode(cfg, params, jax.random.normal(
                jax.random.PRNGKey(3), (B, cfg.frontend.n_tokens, cfg.frontend.embed_dim)))
        logits, aux = forward(cfg, params, toks, **kw)
        extra = cfg.frontend.n_tokens if cfg.family == "vlm" else 0
        assert logits.shape == (B, S + extra, cfg.vocab_size)
        assert not bool(jnp.any(jnp.isnan(logits))), f"NaN logits in {arch}"
        assert not bool(jnp.isnan(aux))

    def test_one_train_step(self, arch):
        cfg = make_reduced(all_configs()[arch])
        if cfg.family in ("encdec", "vlm"):
            pytest.skip("text-only train-step path; frontends covered in forward test")
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_state = init_adamw(params)
        step = jax.jit(make_train_step(cfg, TrainConfig(lr=1e-3, warmup_steps=1, decay_steps=10)))
        it = data_stream(cfg.vocab_size, 4, 16, seed=0)
        tokens, labels = next(it)
        params2, opt_state2, metrics = step(params, opt_state, tokens, labels)
        assert np.isfinite(float(metrics["loss"]))
        assert int(opt_state2.step) == 1
        # lr warms up from 0, so take a second step before asserting movement
        params3, opt_state3, metrics = step(params2, opt_state2, tokens, labels)
        assert np.isfinite(float(metrics["loss"]))
        diffs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), params, params3)
        assert jax.tree.reduce(max, diffs) > 0.0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_param_count(arch):
    """Full (non-reduced) configs match their assigned scale."""
    targets = {
        "gemma3-27b": (27e9, 0.1),
        "glm4-9b": (9.4e9, 0.1),
        "llama4-maverick-400b-a17b": (400e9, 0.05),
        "kimi-k2-1t-a32b": (1.0e12, 0.1),
        "deepseek-67b": (67e9, 0.05),
        "mamba2-370m": (370e6, 0.1),
        "llama3-8b": (8e9, 0.05),
        "recurrentgemma-2b": (2.7e9, 0.15),
        "seamless-m4t-medium": (0.9e9, 0.3),
        "internvl2-1b": (0.5e9, 0.3),
    }
    cfg = all_configs()[arch]
    n = count_params(cfg)
    target, tol = targets[arch]
    assert abs(n - target) / target < tol, f"{arch}: {n/1e9:.2f}B vs target {target/1e9:.2f}B"
