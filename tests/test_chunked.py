"""Chunked prefill-into-pages: the direct-write admission path that replaced
the temp-contiguous-then-scatter prefill (PR 3/4).  Locks in

  * the Pallas prefill-chunk kernel vs its gather-and-concat einsum ref
    (fp + int8 pages, window/softcap, the empty-pool first chunk whose tiles
    are fully masked, and the recompute-overlap masking that keeps a
    shared-prefix key from being counted twice);
  * model-level parity: chunk-by-chunk ``paged_prefill_chunk`` vs the
    one-shot scatter oracle ``paged_prefill_into_slot`` — same logits, same
    subsequent decode, across chunk-boundary edge cases;
  * engine-level greedy parity: ``prefill_mode="chunked"`` (default) vs
    ``prefill_mode="scatter"`` — token-identical across fp and int8 KV,
    glm4 (fully paged) + gemma3 (window-ring mix) + recurrentgemma (LRU
    resume), with prefix sharing on and off, and a prompt-length sweep +/- 1
    around page multiples;
  * the admission state machine: no temp contiguous buffer anywhere in the
    chunked path, long-prompt admissions never stall running decodes for
    more than one chunk budget per tick, mid-prefill preemption resumes
    token-exact, fork admissions wait for a mid-prefill base instead of
    degrading, and shared prefixes skip their prefill FLOPs on fully-paged
    archs (and only there)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import all_configs, make_reduced
from repro.models.model import (
    arch_fully_paged,
    init_paged_caches,
    init_params,
    paged_prefill_chunk,
    paged_prefill_into_slot,
    paged_ragged_decode_step,
)
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request
from repro.serving.kv_pool import BlockTables, KVBlockPool

PRE = [7, 7, 3, 5, 1, 2, 9, 4]  # 2 full pages at page_size=4 — shared preamble


@pytest.fixture(scope="module")
def setup():
    cfg = make_reduced(all_configs()["glm4-9b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def setup_gemma():
    cfg = make_reduced(all_configs()["gemma3-27b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _serve(cfg, params, prompts, n_new, **kw):
    eng = ContinuousEngine(cfg, params, **kw)
    ids = [eng.submit(Request(prompt=p, max_new_tokens=n_new)) for p in prompts]
    done = eng.run_until_done()
    return [done[i].tokens for i in ids], eng


# ---------------------------------------------------------------------------
# Pallas prefill-chunk kernel vs einsum ref
# ---------------------------------------------------------------------------


def _toy_chunk(quantized, n_hist=6):
    key = jax.random.PRNGKey(0)
    C, Hkv, G, dh, ps, Pt = 5, 2, 2, 8, 4, 10
    q = jax.random.normal(key, (C, Hkv, G, dh), jnp.float32)
    kf = jax.random.normal(jax.random.fold_in(key, 1), (Pt, ps, Hkv, dh), jnp.float32)
    vf = jax.random.normal(jax.random.fold_in(key, 2), (Pt, ps, Hkv, dh), jnp.float32)
    ck = jax.random.normal(jax.random.fold_in(key, 3), (C, Hkv, dh), jnp.float32)
    cv = jax.random.normal(jax.random.fold_in(key, 4), (C, Hkv, dh), jnp.float32)
    kpos = np.full((Pt, ps), -1, np.int32)
    hist_pages = [3, 7]
    for t in range(n_hist):
        kpos[hist_pages[t // ps], t % ps] = t
    table = np.array([3, 7, 1, Pt - 1], np.int32)  # page 1 fresh, last unmapped
    qpos = jnp.arange(n_hist, n_hist + C, dtype=jnp.int32)
    if quantized:
        from repro.quant.kv import kv_quantize_values

        kq, ks = kv_quantize_values(kf)
        vq, vs = kv_quantize_values(vf)
    else:
        kq, ks, vq, vs = kf, None, vf, None
    return q, kq, ks, vq, vs, jnp.asarray(kpos), jnp.asarray(table), qpos, ck, cv


class TestPrefillKernel:
    @pytest.mark.parametrize("quantized", [False, True])
    def test_kernel_matches_ref(self, quantized):
        from repro.kernels.attention_prefill_paged import (
            paged_prefill_attention,
            paged_prefill_attention_ref,
        )

        args = _toy_chunk(quantized)
        out_k = paged_prefill_attention(*args, scale=0.3, interpret=True)
        out_r = paged_prefill_attention_ref(*args, scale=0.3)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-5)

    def test_kernel_window_softcap(self):
        from repro.kernels.attention_prefill_paged import (
            paged_prefill_attention,
            paged_prefill_attention_ref,
        )

        args = _toy_chunk(False)
        kw = dict(scale=0.3, causal=True, window=4, softcap=5.0)
        out_k = paged_prefill_attention(*args, interpret=True, **kw)
        out_r = paged_prefill_attention_ref(*args, **kw)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-5)

    def test_empty_pool_first_chunk(self):
        """The first chunk of an unshared admission sees only fully-masked
        page tiles before its own in-flight tile; the masked-tile guard must
        keep them out of the softmax normalizer (finite, ref-equal output)."""
        from repro.kernels.attention_prefill_paged import (
            paged_prefill_attention,
            paged_prefill_attention_ref,
        )

        q, kq, ks, vq, vs, _, table, _, ck, cv = _toy_chunk(False)
        kpos = jnp.full(kq.shape[:2], -1, jnp.int32)
        qpos = jnp.arange(q.shape[0], dtype=jnp.int32)
        args = (q, kq, ks, vq, vs, kpos, table, qpos, ck, cv)
        out_k = paged_prefill_attention(*args, scale=0.3, interpret=True)
        out_r = paged_prefill_attention_ref(*args, scale=0.3)
        assert np.isfinite(np.asarray(out_k)).all()
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-5)

    def test_recompute_overlap_counts_keys_once(self):
        """When a shared-prefix admission recomputes the prefix (ring/SSM
        archs), the chunk's positions are live in the pool AND in flight.
        Pool keys at positions >= the chunk start must be masked: the result
        equals attending with those pool entries absent."""
        from repro.kernels.attention_prefill_paged import (
            paged_prefill_attention,
            paged_prefill_attention_ref,
        )

        q, kq, ks, vq, vs, kpos, table, _, ck, cv = _toy_chunk(False, n_hist=6)
        qpos = jnp.arange(2, 2 + q.shape[0], dtype=jnp.int32)  # overlaps hist 2..5
        full = (q, kq, ks, vq, vs, kpos, table, qpos, ck, cv)
        # oracle: the same pool with the overlapping entries truly emptied
        kpos_clean = jnp.where(kpos >= 2, -1, kpos)
        clean = (q, kq, ks, vq, vs, kpos_clean, table, qpos, ck, cv)
        out_k = paged_prefill_attention(*full, scale=0.3, interpret=True)
        out_r = paged_prefill_attention_ref(*clean, scale=0.3)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-5)


# ---------------------------------------------------------------------------
# Model level: chunk-by-chunk direct write vs the scatter oracle
# ---------------------------------------------------------------------------


class TestModelChunkParity:
    @pytest.mark.parametrize("kv_bits", [0, 8])
    @pytest.mark.parametrize("arch", ["glm4-9b", "gemma3-27b"])
    def test_chunked_matches_scatter(self, arch, kv_bits):
        """Chunked direct-write prefill must reproduce the scatter path's
        last-token logits and subsequent decode (fp: ~exact; int8: within
        quantization noise of reading earlier chunks back dequantized)."""
        cfg = make_reduced(all_configs()[arch])
        params = init_params(cfg, jax.random.PRNGKey(0))
        cap, ps, n_pages = 24, 4, 10
        prompt = [3, 5, 7, 9, 11, 2, 4, 6, 8, 1]  # 10 tokens, 3 pages

        def admit(chunks):
            caches = init_paged_caches(cfg, 2, cap, n_pages=n_pages, page_size=ps,
                                       kv_bits=kv_bits)
            pool = KVBlockPool(n_pages, ps)
            tables = BlockTables(2, -(-cap // ps))
            tables.append(0, pool.alloc(pool.pages_for(len(prompt)), owner=0))
            row = jnp.asarray(tables.row(0))
            if chunks is None:  # scatter oracle
                lg, caches = paged_prefill_into_slot(
                    cfg, params, jnp.asarray([prompt], jnp.int32),
                    jnp.arange(len(prompt), dtype=jnp.int32)[None],
                    jnp.asarray(0, jnp.int32), caches, row,
                    capacity=cap, kv_bits=kv_bits)
            else:
                for j, (s, e) in enumerate(chunks):
                    lg, caches = paged_prefill_chunk(
                        cfg, params, jnp.asarray([prompt[s:e]], jnp.int32),
                        jnp.arange(s, e, dtype=jnp.int32)[None],
                        jnp.asarray(0, jnp.int32), caches, row,
                        capacity=cap, kv_bits=kv_bits, page_size=ps,
                        reset=(j == 0))
            tables.append(0, pool.alloc(1, owner=0))
            tk = jnp.asarray([[1], [1]], jnp.int32)
            posd = jnp.asarray([len(prompt), 0], jnp.int32)
            act = jnp.asarray([True, False])
            ld, _ = paged_ragged_decode_step(cfg, params, tk, posd, act, caches,
                                             jnp.asarray(tables.table))
            return np.asarray(lg), np.asarray(ld[0])

        lg_s, ld_s = admit(None)
        for split in ([(0, 10)], [(0, 4), (4, 8), (8, 10)], [(0, 8), (8, 10)]):
            lg_c, ld_c = admit(split)
            atol = 1e-4 if kv_bits == 0 else 0.05
            np.testing.assert_allclose(lg_c, lg_s, atol=atol)
            np.testing.assert_allclose(ld_c, ld_s, atol=atol)
            assert np.argmax(lg_c) == np.argmax(lg_s)
            assert np.argmax(ld_c) == np.argmax(ld_s)


# ---------------------------------------------------------------------------
# Engine level: greedy parity chunked vs scatter
# ---------------------------------------------------------------------------


class TestEngineParity:
    @pytest.mark.parametrize("prefix", [False, True])
    @pytest.mark.parametrize("kv_bits", [0, 8])
    def test_matches_scatter_greedy(self, setup, kv_bits, prefix):
        """Acceptance: token-identical greedy outputs, chunked (multi-chunk
        forced by a small budget) vs the PR 3/4 scatter path — fp and int8
        KV, prefix sharing on and off."""
        cfg, params = setup
        prompts = [PRE + [11], PRE + [12, 13], [9, 8, 7], PRE + [14, 15, 16]]
        kw = dict(slots=3, capacity=32, kv_cache_bits=kv_bits, paged=True,
                  page_size=4, n_pages=24, prefix_sharing=prefix)
        want, _ = _serve(cfg, params, prompts, 5, prefill_mode="scatter", **kw)
        got, eng = _serve(cfg, params, prompts, 5, prefill_mode="chunked",
                          prefill_chunk=4, **kw)
        assert got == want, (got, want)
        assert eng.pool.free_count == eng.n_pages
        if prefix:
            assert eng.prefix_hits >= 1

    @pytest.mark.parametrize("prefix", [False, True])
    def test_window_ring_mix_gemma3(self, setup_gemma, prefix):
        """Window rings advance chunk-by-chunk while global layers write
        pages directly; a shared prefix is recomputed (rings must be rebuilt)
        but its pages are still shared and never written."""
        cfg, params = setup_gemma
        assert not arch_fully_paged(cfg)
        prompts = [PRE + [11, 12], PRE + [13], [1, 2, 3]]
        kw = dict(slots=2, capacity=24, paged=True, page_size=4, n_pages=12,
                  prefix_sharing=prefix)
        want, _ = _serve(cfg, params, prompts, 6, prefill_mode="scatter", **kw)
        got, eng = _serve(cfg, params, prompts, 6, prefill_mode="chunked",
                          prefill_chunk=4, **kw)
        assert got == want, (got, want)
        if prefix:
            assert eng.prefix_hits >= 1
            assert eng.prefill_tokens_skipped == 0  # rings force the recompute

    def test_ring_size_chunk_starting_mid_ring(self, setup_gemma):
        """Regression: a chunk of EXACTLY ring size landing at a position
        that is not a ring multiple (prompt 20, chunk 12 -> final chunk
        [12:20) of size 8 == window at offset 12 % 8 = 4) must scatter at
        pos % cap, not rebuild at index 0 — the rebuild layout breaks the
        ring invariant slot == pos % cap and evicts the wrong tokens on the
        next decode write."""
        cfg, params = setup_gemma
        prompt = [(5 * i) % 89 + 1 for i in range(20)]
        kw = dict(slots=1, capacity=32, paged=True, page_size=4, n_pages=8)
        want, _ = _serve(cfg, params, [prompt], 8, prefill_mode="scatter", **kw)
        got, _ = _serve(cfg, params, [prompt], 8, prefill_mode="chunked",
                        prefill_chunk=12, **kw)
        assert got == want, (got, want)

    def test_lru_resume_recurrentgemma(self):
        """RG-LRU recurrence + conv prefix resume across chunks (hybrid arch
        with local-attention rings and no paged layers at all)."""
        cfg = make_reduced(all_configs()["recurrentgemma-2b"])
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompts = [[1, 2, 3, 4, 5, 6, 7, 8, 9, 10], [11, 12, 13]]
        kw = dict(slots=2, capacity=24, paged=True, page_size=4, n_pages=12)
        want, _ = _serve(cfg, params, prompts, 5, prefill_mode="scatter", **kw)
        got, _ = _serve(cfg, params, prompts, 5, prefill_mode="chunked",
                        prefill_chunk=4, **kw)
        assert got == want, (got, want)

    def test_slot_reuse_resets_recurrent_state(self):
        """Regression: the FIRST chunk of an admission must reset the slot's
        per-slot leaves — the row still holds the previous occupant's
        SSM/LRU recurrence and conv prefix, and `prefill_chunk` mode resumes
        from the cache (the scatter path rewrote the whole row implicitly).
        Back-to-back traffic through one slot must match fresh serving."""
        cfg = make_reduced(all_configs()["recurrentgemma-2b"])
        params = init_params(cfg, jax.random.PRNGKey(0))
        kw = dict(slots=1, capacity=24, paged=True, page_size=4, n_pages=12)
        eng = ContinuousEngine(cfg, params, prefill_chunk=4, **kw)
        outs = []
        for p in ([1, 2, 3, 4, 5, 6, 7, 8, 9, 10], [11, 12, 13]):
            rid = eng.submit(Request(prompt=p, max_new_tokens=5))
            outs.append(eng.run_until_done()[rid].tokens)
        for p, got in zip(([1, 2, 3, 4, 5, 6, 7, 8, 9, 10], [11, 12, 13]), outs):
            want, _ = _serve(cfg, params, [p], 5, prefill_mode="scatter", **kw)
            assert got == want[0], (p, got, want[0])

    def test_slot_reuse_resets_window_ring(self, setup_gemma):
        """Same regression for window rings: the second occupant is SHORTER
        than the window, so the previous occupant's stale ring entries (at
        positions <= the new queries') would survive the causal mask if the
        first chunk resumed instead of resetting."""
        cfg, params = setup_gemma
        kw = dict(slots=1, capacity=24, paged=True, page_size=4, n_pages=12)
        eng = ContinuousEngine(cfg, params, prefill_chunk=4, **kw)
        prompts = ([21, 22, 23, 24, 25, 26, 27, 28, 29, 30], [31, 32, 33])
        outs = []
        for p in prompts:
            rid = eng.submit(Request(prompt=p, max_new_tokens=5))
            outs.append(eng.run_until_done()[rid].tokens)
        for p, got in zip(prompts, outs):
            want, _ = _serve(cfg, params, [p], 5, prefill_mode="scatter", **kw)
            assert got == want[0], (p, got, want[0])

    def test_chunk_boundary_sweep(self, setup):
        """Prompt lengths +/- 1 around page and chunk multiples (page_size 4,
        chunk 8): partial first chunks, exact-fit chunks, 1-token remainders."""
        cfg, params = setup
        kw = dict(slots=1, capacity=32, paged=True, page_size=4, n_pages=8)
        for n in (3, 4, 5, 7, 8, 9, 11, 12, 13, 15, 16, 17):
            prompt = [(7 * i + n) % 97 + 1 for i in range(n)]
            want, _ = _serve(cfg, params, [prompt], 4, prefill_mode="scatter", **kw)
            got, eng = _serve(cfg, params, [prompt], 4, prefill_mode="chunked",
                              prefill_chunk=8, **kw)
            assert got == want, (n, got, want)
            assert eng.pool.free_count == eng.n_pages, n

    @pytest.mark.parametrize("kv_bits", [0, 8])
    def test_n_samples_fork_with_midprefill_base(self, setup, kv_bits):
        """submit_n while the base is still mid-prefill: the forks wait at
        the queue head (never degrade), share ALL the base's pages once it
        reaches its admission state, and match independent serving."""
        cfg, params = setup
        req = Request(prompt=PRE + [31, 32], max_new_tokens=6)
        oracle = ContinuousEngine(cfg, params, slots=3, capacity=32, paged=True,
                                  page_size=4, n_pages=24, kv_cache_bits=kv_bits,
                                  prefill_chunk=4)
        rids_o = oracle.submit_n(req, 3)
        done_o = oracle.run_until_done()
        eng = ContinuousEngine(cfg, params, slots=3, capacity=32, paged=True,
                               page_size=4, n_pages=24, prefix_sharing=True,
                               kv_cache_bits=kv_bits, prefill_chunk=4)
        rids = eng.submit_n(req, 3)
        # base got one 4-token chunk at admission (prompt is 10 tokens) and
        # is still prefilling; both forks must be queued, not degraded
        assert eng.slots[0].prefilling and sum(s.active for s in eng.slots) == 1
        assert len(eng.queue) == 2
        while eng.slots[0].prefilling:
            eng.step()  # base finishes -> forks admitted as page-aligned forks
        assert eng.prefix_hits == 2  # both rode _admit_fork, neither degraded
        done = eng.run_until_done()
        assert eng.cow_copies >= 2  # boundary page forked away per diverger
        assert [done[r].tokens for r in rids] == [done_o[r].tokens for r in rids_o]
        assert eng.pool.free_count == eng.n_pages
        eng.pool.check()


# ---------------------------------------------------------------------------
# Admission state machine: interleaving, bounded stalls, no temp buffer
# ---------------------------------------------------------------------------


class TestAdmissionStateMachine:
    def test_no_temp_contiguous_buffer(self, setup):
        """Acceptance: the chunked admission path never touches the scatter
        prefill (whose temp [1, capacity] cache was the double buffer)."""
        cfg, params = setup
        eng = ContinuousEngine(cfg, params, slots=2, capacity=32, paged=True,
                               page_size=4, n_pages=16, prefill_chunk=4)

        def boom(*a, **k):  # pragma: no cover - must never run
            raise AssertionError("scatter prefill called on the chunked path")

        eng._prefill = boom
        prompts = [[1, 2, 3, 4, 5, 6, 7, 8, 9], [9, 8, 7]]
        ids = [eng.submit(Request(prompt=p, max_new_tokens=5)) for p in prompts]
        done = eng.run_until_done()
        assert all(len(done[i].tokens) == 5 for i in ids)

    def test_long_admission_never_stalls_decodes(self, setup):
        """A long-prompt admission interleaves with running decodes: every
        tick decodes all non-prefilling active slots, and per-tick prefill
        compute never exceeds the chunk budget."""
        cfg, params = setup
        chunk = 4
        eng = ContinuousEngine(cfg, params, slots=3, capacity=64, paged=True,
                               page_size=4, n_pages=48, prefill_chunk=chunk)
        a = eng.submit(Request(prompt=[1, 2, 3], max_new_tokens=30))
        b = eng.submit(Request(prompt=[4, 5, 6], max_new_tokens=30))
        eng.step()
        long_id = eng.submit(Request(prompt=[(i % 50) + 1 for i in range(40)],
                                     max_new_tokens=4))
        li = next(i for i, s in enumerate(eng.slots) if s.request_id == long_id)
        assert eng.slots[li].prefilling  # one chunk at admission, 36 to go
        stall_free_ticks = 0
        while eng.slots[li].active and eng.slots[li].prefilling:
            before = [len(eng.slots[i].generated) for i in range(3)]
            eng.step()
            m = eng.last_metrics
            assert m["prefill_tokens"] <= chunk
            for i in range(3):
                if i != li and eng.slots[i].active:
                    assert len(eng.slots[i].generated) == before[i] + 1, \
                        "a running decode stalled behind the admission"
                    stall_free_ticks += 1
        assert stall_free_ticks >= 8  # 36 tokens / 4-token chunks = 9 ticks
        done = eng.run_until_done()
        assert len(done) == 3
        # token-exact vs the same traffic served by the scatter engine
        oracle = ContinuousEngine(cfg, params, slots=3, capacity=64, paged=True,
                                  page_size=4, n_pages=48, prefill_mode="scatter")
        oa = oracle.submit(Request(prompt=[1, 2, 3], max_new_tokens=30))
        ob = oracle.submit(Request(prompt=[4, 5, 6], max_new_tokens=30))
        oracle.step()
        oc = oracle.submit(Request(prompt=[(i % 50) + 1 for i in range(40)],
                                   max_new_tokens=4))
        done_o = oracle.run_until_done()
        assert done[a].tokens == done_o[oa].tokens
        assert done[b].tokens == done_o[ob].tokens
        assert done[long_id].tokens == done_o[oc].tokens

    def test_midprefill_preemption_resumes_exactly(self, setup):
        """Preempting a slot that is still prefilling frees its pages and
        re-queues (prompt, generated-so-far); the re-admission restarts the
        chunked prefill and finishes token-exact."""
        cfg, params = setup
        p = [(3 * i) % 23 + 1 for i in range(14)]
        want, _ = _serve(cfg, params, [p], 6, slots=1, capacity=32, paged=True,
                         page_size=4, n_pages=8, prefill_mode="scatter")
        eng = ContinuousEngine(cfg, params, slots=1, capacity=32, paged=True,
                               page_size=4, n_pages=8, prefill_chunk=4)
        rid = eng.submit(Request(prompt=p, max_new_tokens=6))
        assert eng.slots[0].prefilling
        eng._preempt(0)  # yank it mid-prefill
        assert eng.pool.free_count == eng.n_pages
        done = eng.run_until_done()
        assert eng.preemptions == 1
        assert done[rid].tokens == want[0], (done[rid].tokens, want[0])

    def test_shared_prefix_skips_prefill_flops(self, setup):
        """Acceptance: on a fully-paged arch, a prefix-sharing admission
        starts its chunks AFTER the shared pages — measured prefill compute
        drops by exactly the shared token count, outputs unchanged."""
        cfg, params = setup
        assert arch_fully_paged(cfg)
        prompts = [PRE + [11, 12], PRE + [13, 14], PRE + [15, 16]]
        kw = dict(slots=3, capacity=32, paged=True, page_size=4, n_pages=24,
                  prefill_chunk=4)

        def serve_staggered(**extra):
            eng = ContinuousEngine(cfg, params, **kw, **extra)
            ids = [eng.submit(Request(prompt=prompts[0], max_new_tokens=4))]
            while eng.slots[0].prefilling:
                eng.step()  # finish writing the preamble before the others arrive
            ids += [eng.submit(Request(prompt=p, max_new_tokens=4)) for p in prompts[1:]]
            done = eng.run_until_done()
            return [done[i].tokens for i in ids], eng

        want, base = serve_staggered()
        got, eng = serve_staggered(prefix_sharing=True)
        assert got == want, (got, want)
        assert eng.prefix_hits == 2
        # admissions 2 and 3 each skipped the 8-token (2-page) preamble
        assert eng.prefill_tokens_skipped == 2 * len(PRE)
        assert eng.prefill_tokens_total == base.prefill_tokens_total - 2 * len(PRE)

    def test_concurrent_admissions_share_progressively(self, setup):
        """A second admission arriving while the first is mid-prefill shares
        the pages the first has ALREADY written (progressive index
        registration), not nothing."""
        cfg, params = setup
        long_pre = [(5 * i) % 17 + 1 for i in range(12)]
        eng = ContinuousEngine(cfg, params, slots=2, capacity=32, paged=True,
                               page_size=4, n_pages=24, prefix_sharing=True,
                               prefill_chunk=4)
        eng.submit(Request(prompt=long_pre + [99], max_new_tokens=3))
        assert eng.slots[0].prefilling  # 4 of 13 tokens written
        eng.submit(Request(prompt=long_pre + [98], max_new_tokens=3))
        assert eng.prefix_hits == 1  # shared the one already-written page
        assert eng.prefill_tokens_skipped == 4
        done = eng.run_until_done()
        want, _ = _serve(cfg, params, [long_pre + [99], long_pre + [98]], 3,
                         slots=2, capacity=32, paged=True, page_size=4,
                         n_pages=24, prefill_mode="scatter")
        assert [done[i].tokens for i in sorted(done)] == want
        assert eng.pool.free_count == eng.n_pages

    def test_interleaving_fuzz(self, setup):
        """Randomized mixed traffic (short and long prompts, interleaved
        submits and ticks): per-tick prefill compute never exceeds the chunk
        budget, every decode-eligible slot advances every tick, and all
        outputs come back token-exact vs a scatter-mode engine fed the
        identical submissions."""
        cfg, params = setup
        chunk = 4
        for seed in (0, 1, 2):
            rng = np.random.default_rng(seed)
            eng = ContinuousEngine(cfg, params, slots=3, capacity=48, paged=True,
                                   page_size=4, n_pages=64, prefill_chunk=chunk)
            oracle = ContinuousEngine(cfg, params, slots=3, capacity=48, paged=True,
                                      page_size=4, n_pages=64,
                                      prefill_mode="scatter")
            submitted = 0
            for _ in range(80):
                op = rng.choice(["submit", "step", "step", "step"])
                if op == "submit" and submitted < 8:
                    n = int(rng.choice([2, 3, 20, 28]))  # short or long prompt
                    prompt = [int(t) for t in rng.integers(1, 97, size=n)]
                    req = Request(prompt=prompt, max_new_tokens=int(rng.integers(2, 5)))
                    assert eng.submit(req) == oracle.submit(req)
                    submitted += 1
                else:
                    eligible = sum(s.active and not s.prefilling for s in eng.slots)
                    ticked = eng.step()
                    oracle.step()
                    if ticked:
                        m = eng.last_metrics
                        assert m["prefill_tokens"] <= chunk, m
                        assert m["tokens_this_tick"] >= eligible, \
                            "a decode-eligible slot stalled behind an admission"
            done = eng.run_until_done()
            done_o = oracle.run_until_done()
            assert set(done) == set(done_o) and len(done) == submitted
            for rid in done_o:
                assert done[rid].tokens == done_o[rid].tokens, (seed, rid)
            assert eng.pool.free_count == eng.n_pages

    def test_metrics_surface_prefill_counters(self, setup):
        cfg, params = setup
        _, eng = _serve(cfg, params, [[1, 2, 3, 4, 5, 6, 7, 8, 9]], 3, slots=2,
                        capacity=16, paged=True, page_size=4, prefill_chunk=4)
        m = eng.last_metrics
        for key in ("prefill_tokens", "tokens_this_tick", "free_pages",
                    "preemptions"):
            assert key in m, key
        assert any(r["prefill_tokens"] > 0 for r in eng.metrics_log)
        assert eng.prefill_tokens_total == 9


# ---------------------------------------------------------------------------
# Engine level: batched multi-slot prefill (the fused tick) vs per-slot chunked
# ---------------------------------------------------------------------------


class TestBatchedPrefillTick:
    """``prefill_mode="batched"``: one fixed-shape jitted call advances EVERY
    mid-prefill slot's next chunk per tick, so a steady tick issues at most
    {one batched prefill, one batched decode}.  Padding rows must be inert by
    construction (trash-page routing / ring scatter drops / dt=0 / a=1,b=0),
    so outputs are token-identical to the per-slot chunked engine."""

    @pytest.mark.parametrize("prefix", [False, True])
    @pytest.mark.parametrize(
        "arch", ["glm4-9b", "gemma3-27b", "recurrentgemma-2b"])
    def test_batched_matches_chunked_greedy(self, arch, prefix):
        """Acceptance: token-identical greedy outputs across fully-paged
        (glm4), window-ring mix (gemma3), and LRU/SSM resume
        (recurrentgemma), with prefix sharing on and off, under enough
        concurrent admissions that several slots are mid-prefill at once."""
        cfg = make_reduced(all_configs()[arch])
        params = init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        # 5 prompts onto 4 slots: the queued 5th repeats PRE, so it admits
        # AFTER the prefix pages are indexed -> exercises a prefix hit under
        # the batched tick (the first 4 admit before anything is indexed)
        prompts = [PRE + [int(t) for t in rng.randint(1, 97, size=n)]
                   for n in (13, 1)] + [[9, 8, 7], [1, 2]] + \
                  [PRE + [int(t) for t in rng.randint(1, 97, size=5)]]
        kw = dict(slots=4, capacity=32, paged=True, page_size=4,
                  prefill_chunk=8, prefix_sharing=prefix)
        want, _ = _serve(cfg, params, prompts, 6, prefill_mode="chunked", **kw)
        got, eng = _serve(cfg, params, prompts, 6, prefill_mode="batched", **kw)
        assert got == want, (got, want)
        assert eng.pool.free_count == eng.n_pages
        if prefix:
            assert eng.prefix_hits >= 1
        # several slots really were mid-prefill in one batched call
        assert any(m.get("batched_prefill_occupancy", 0) > 0.25
                   for m in eng.metrics_log)

    def test_one_prefill_dispatch_per_tick(self, setup):
        """>= 3 concurrent mid-prefill admissions advance in ONE batched
        jitted call: steady ticks issue at most 2 primary dispatches
        (batched prefill + decode), and the jitted-calls gauge proves it."""
        cfg, params = setup
        prompts = [[int(t) for t in np.arange(1, 22 + i)] for i in range(3)]
        eng = ContinuousEngine(cfg, params, slots=4, capacity=32, paged=True,
                               page_size=4, prefill_chunk=4,
                               prefill_mode="batched")
        for p in prompts:
            eng.submit(Request(prompt=p, max_new_tokens=4))
        eng.step()  # admission tick: all 3 join the one batched call
        m = eng.last_metrics
        assert m["prefill_tokens"] == 12  # 3 rows x 4-token chunk, one call
        assert m["batched_prefill_occupancy"] == 0.75
        # registry: the batched entry replaces the first/cont chunk family
        # and is primary alongside decode
        fns = eng.jitted_functions()
        assert "prefill_chunk_batched" in fns
        assert "prefill_chunk_first" not in fns and "prefill_chunk_cont" not in fns
        primaries = [n for n, (_, _, p) in fns.items() if p]
        assert sorted(primaries) == ["decode", "prefill_chunk_batched"]
        eng.run_until_done()
        # steady ticks (no admissions/releases): <= 2 jitted calls each
        steady = [m for m in eng.metrics_log
                  if m.get("prefill_tokens", 0) and m.get("tokens_this_tick")]
        assert steady and all(m["jitted_calls"] <= 2 for m in steady)

    def test_batched_requires_paged(self, setup):
        cfg, params = setup
        with pytest.raises(ValueError, match="batched.*paged"):
            ContinuousEngine(cfg, params, slots=2, capacity=16,
                             prefill_mode="batched")

    def test_preemption_resumes_exactly(self, setup):
        """Mid-prefill preemption under the batched tick resumes token-exact
        (the reset flag rebuilds the victim's row state on re-admission)."""
        cfg, params = setup
        long = [int(t) for t in np.arange(1, 41)]
        short = [5, 4, 3]
        kw = dict(slots=2, capacity=48, paged=True, page_size=4, n_pages=14,
                  prefill_chunk=4)  # tight pool forces a preemption
        want, _ = _serve(cfg, params, [long, short], 4,
                         prefill_mode="chunked", **kw)
        got, eng = _serve(cfg, params, [long, short], 4,
                          prefill_mode="batched", **kw)
        assert got == want, (got, want)
        assert eng.pool.free_count == eng.n_pages
