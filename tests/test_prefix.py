"""Prefix-sharing / copy-on-write paged serving: physical page sharing
(refcount-asserted), CoW isolation, page-aligned parallel sampling, greedy
parity against the non-shared paged engine (the strict oracle — the decode
read path is untouched by sharing, tables just point at shared pages), int8
and window-ring composition, and a randomized ~200-step scheduler fuzz."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import all_configs, make_reduced
from repro.models.model import _layer_entries, init_params
from repro.serving.continuous import ContinuousEngine
from repro.serving.engine import Request
from repro.serving.prefix_index import PrefixIndex

from tests._hyp import given, settings, st

PRE = [7, 7, 3, 5, 1, 2, 9, 4]  # 2 full pages at page_size=4 — shared preamble


@pytest.fixture(scope="module")
def setup():
    cfg = make_reduced(all_configs()["glm4-9b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _serve(cfg, params, prompts, n_new, **kw):
    eng = ContinuousEngine(cfg, params, **kw)
    ids = [eng.submit(Request(prompt=p, max_new_tokens=n_new)) for p in prompts]
    done = eng.run_until_done()
    return [done[i].tokens for i in ids], eng


def _first_paged_self(cfg, caches):
    for sk, pk, ls, paged in _layer_entries(cfg):
        if paged:
            return caches[sk][pk]["self"]
    raise AssertionError("no paged layer")


# ---------------------------------------------------------------------------
# Prefix index unit behavior
# ---------------------------------------------------------------------------


class TestPrefixIndex:
    def test_insert_lookup_full_pages_only(self):
        idx = PrefixIndex(4)
        toks = list(range(10))  # 2 full pages + partial tail
        assert idx.insert(toks, [5, 9]) == 2
        assert idx.lookup(toks) == [5, 9]
        assert idx.lookup(toks[:7]) == [5]  # only 1 full page matches
        assert idx.lookup(toks, max_tokens=8) == [5, 9]
        assert idx.lookup(toks, max_tokens=7) == [5]  # admission's len-1 cap
        assert idx.lookup([1] + toks[1:]) == []  # different first chunk

    def test_first_writer_wins_and_eviction_holes(self):
        idx = PrefixIndex(4)
        toks = list(range(8))
        idx.insert(toks, [1, 2])
        assert idx.insert(toks, [3, 4]) == 0  # duplicates keep existing pages
        assert idx.lookup(toks) == [1, 2]
        idx.evict_pages([1])  # mid-chain hole: deeper match must not leak
        assert idx.lookup(toks) == []
        assert len(idx) == 1  # page 2's mapping survives, unreachable
        idx.insert(toks, [9, 7])  # refill the hole; chunk 1 keeps page 2
        assert idx.lookup(toks) == [9, 2]
        idx.evict_pages([9, 2])
        assert len(idx) == 0

    def test_duplicate_page_at_new_path_raises(self):
        idx = PrefixIndex(2)
        idx.insert([1, 2], [0])
        with pytest.raises(ValueError, match="already indexed"):
            idx.insert([3, 4], [0])


# ---------------------------------------------------------------------------
# Physical sharing + CoW isolation (refcount-asserted)
# ---------------------------------------------------------------------------


class TestPhysicalSharing:
    def test_two_slots_share_common_prefix_pages(self, setup):
        """Acceptance: two admitted requests with a >=2-page common prefix
        physically share those pages — same ids in both tables, refcount 2,
        occupancy counting them once."""
        cfg, params = setup
        eng = ContinuousEngine(cfg, params, slots=2, capacity=32, paged=True,
                               page_size=4, n_pages=16, prefix_sharing=True)
        eng.submit(Request(prompt=PRE + [11], max_new_tokens=4))
        eng.submit(Request(prompt=PRE + [12, 13], max_new_tokens=4))
        assert all(s.active for s in eng.slots)
        shared = [int(p) for p in eng.tables.row(0)[:2]]
        assert [int(p) for p in eng.tables.row(1)[:2]] == shared
        assert all(eng.pool.refcount(p) == 2 for p in shared)
        # 2 shared + 1 private tail page each — 4 physical pages, not 6
        assert eng.pool.used_count == 4
        assert eng.prefix_hits == 1 and eng.prefix_hit_tokens == len(PRE)
        done = eng.run_until_done()
        assert len(done) == 2
        assert eng.pool.free_count == eng.n_pages and len(eng.prefix) == 0
        eng.pool.check()

    def test_cow_never_mutates_page_visible_to_another_slot(self, setup):
        """Fork two samples off one prompt whose boundary page is partial;
        the first divergent append must copy, and the shared prompt entries
        of the original page must be bit-identical afterwards."""
        cfg, params = setup
        eng = ContinuousEngine(cfg, params, slots=2, capacity=32, paged=True,
                               page_size=4, n_pages=16, prefix_sharing=True)
        prompt = PRE + [11, 12]  # 10 tokens: boundary page holds pos 8..9
        eng.submit_n(Request(prompt=prompt, max_new_tokens=5), 2)
        boundary = int(eng.tables.row(0)[2])
        assert int(eng.tables.row(1)[2]) == boundary
        assert eng.pool.refcount(boundary) == 2
        pool0 = _first_paged_self(cfg, eng.caches)
        before_k = np.asarray(pool0["k"][:, boundary, :2])  # prompt entries
        before_pos = np.asarray(pool0["pos"][:, boundary, :2])
        eng.step()
        assert eng.cow_copies >= 1
        # tables diverged at the boundary entry; both slots still share 8..9
        assert int(eng.tables.row(0)[2]) != int(eng.tables.row(1)[2])
        pool1 = _first_paged_self(cfg, eng.caches)
        for b in (int(eng.tables.row(0)[2]), int(eng.tables.row(1)[2])):
            np.testing.assert_array_equal(np.asarray(pool1["pos"][:, b, :2]), before_pos)
            np.testing.assert_array_equal(np.asarray(pool1["k"][:, b, :2]), before_k)
        done = eng.run_until_done()
        assert len(done) == 2
        assert eng.pool.free_count == eng.n_pages
        eng.pool.check()

    def test_preempted_sharer_decrefs_not_frees(self, setup):
        """Engine-level regression for the owner-tag release bug: preempting
        a slot that shares prefix pages must leave them live for the other
        slot, and both requests must still finish token-exact."""
        cfg, params = setup
        prompts = [PRE + [21], PRE + [22]]
        want, _ = _serve(cfg, params, prompts, 6, slots=2, capacity=32,
                         paged=True, page_size=4, n_pages=16)
        eng = ContinuousEngine(cfg, params, slots=2, capacity=32, paged=True,
                               page_size=4, n_pages=16, prefix_sharing=True)
        ids = [eng.submit(Request(prompt=p, max_new_tokens=6)) for p in prompts]
        shared = [int(p) for p in eng.tables.row(0)[:2]]
        assert all(eng.pool.refcount(p) == 2 for p in shared)
        eng._preempt(1)  # the sharer departs mid-flight
        assert all(eng.pool.refcount(p) == 1 for p in shared), \
            "release must decref shared pages, not free them"
        assert len(eng.prefix) > 0  # still-live pages stay indexed
        done = eng.run_until_done()
        assert [done[i].tokens for i in ids] == want
        assert eng.preemptions == 1
        assert eng.pool.free_count == eng.n_pages and len(eng.prefix) == 0
        eng.pool.check()


# ---------------------------------------------------------------------------
# Greedy parity vs the non-shared paged engine (fp, int8, window rings)
# ---------------------------------------------------------------------------


class TestPrefixParity:
    @pytest.mark.parametrize("prefill_mode", ["chunked", "scatter"])
    @pytest.mark.parametrize("kv_bits", [0, 8])
    def test_matches_unshared_engine_greedy(self, setup, kv_bits, prefill_mode):
        """Acceptance: token-identical greedy outputs with sharing enabled,
        fp and int8 KV pages, while admissions actually hit the index —
        under both admission paths (the scatter oracle's scatter_start
        trash-routing is exactly what sharing exercises there)."""
        cfg, params = setup
        prompts = [PRE + [11], PRE + [12, 13], PRE + [14, 15, 16], [9, 8, 7]]
        want, _ = _serve(cfg, params, prompts, 5, slots=3, capacity=32,
                         kv_cache_bits=kv_bits, paged=True, page_size=4,
                         n_pages=24, prefill_mode=prefill_mode)
        got, eng = _serve(cfg, params, prompts, 5, slots=3, capacity=32,
                          kv_cache_bits=kv_bits, paged=True, page_size=4,
                          n_pages=24, prefix_sharing=True,
                          prefill_mode=prefill_mode)
        assert got == want, (got, want)
        assert eng.prefix_hits >= 2
        assert eng.pool.free_count == eng.n_pages and len(eng.prefix) == 0

    @pytest.mark.parametrize("kv_bits", [0, 8])
    def test_n_samples_fork_matches_independent_serving(self, setup, kv_bits):
        """Page-aligned parallel sampling: n greedy samples share all prompt
        pages (boundary included), diverge via CoW, and match n independent
        submissions of the same prompt bit-for-bit."""
        cfg, params = setup
        req = Request(prompt=PRE + [31, 32], max_new_tokens=6)
        oracle = ContinuousEngine(cfg, params, slots=3, capacity=32, paged=True,
                                  page_size=4, n_pages=24,
                                  kv_cache_bits=kv_bits)
        rids_o = oracle.submit_n(req, 3)  # no sharing: independent admissions
        done_o = oracle.run_until_done()
        eng = ContinuousEngine(cfg, params, slots=3, capacity=32, paged=True,
                               page_size=4, n_pages=24, prefix_sharing=True,
                               kv_cache_bits=kv_bits)
        rids = eng.submit_n(req, 3)
        # all three tables alias the same pages before divergence
        rows = [list(map(int, eng.tables.row(i)[:3])) for i in range(3)]
        assert rows[0] == rows[1] == rows[2]
        assert all(eng.pool.refcount(p) == 3 for p in rows[0])
        done = eng.run_until_done()
        assert [done[r].tokens for r in rids] == [done_o[r].tokens for r in rids_o]
        assert eng.cow_copies >= 2  # two of three holders had to fork away
        assert eng.pool.free_count == eng.n_pages
        eng.pool.check()

    def test_window_ring_mix_gemma3(self):
        """Sliding-window layers keep per-slot rings while global layers
        share pages — sharing parity and fork-copied rings on a local+global
        arch (gemma3)."""
        cfg = make_reduced(all_configs()["gemma3-27b"])
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompts = [PRE + [11, 12], PRE + [13], [1, 2, 3]]
        want, _ = _serve(cfg, params, prompts, 6, slots=2, capacity=24,
                         paged=True, page_size=4, n_pages=12)
        got, eng = _serve(cfg, params, prompts, 6, slots=2, capacity=24,
                          paged=True, page_size=4, n_pages=12,
                          prefix_sharing=True)
        assert got == want, (got, want)
        assert eng.prefix_hits >= 1
        # forks must row-copy the window rings (paged_copy_slot_leaves)
        req = Request(prompt=PRE + [41, 42], max_new_tokens=5)
        oracle = ContinuousEngine(cfg, params, slots=2, capacity=24, paged=True,
                                  page_size=4, n_pages=12)
        rids_o = oracle.submit_n(req, 2)
        done_o = oracle.run_until_done()
        eng2 = ContinuousEngine(cfg, params, slots=2, capacity=24, paged=True,
                                page_size=4, n_pages=12, prefix_sharing=True)
        rids = eng2.submit_n(req, 2)
        done = eng2.run_until_done()
        assert [done[r].tokens for r in rids] == [done_o[r].tokens for r in rids_o]
        assert eng2.pool.free_count == eng2.n_pages

    def test_metrics_surface_sharing_counters(self, setup):
        cfg, params = setup
        _, eng = _serve(cfg, params, [PRE + [1], PRE + [2]], 3, slots=2,
                        capacity=16, paged=True, page_size=4,
                        prefix_sharing=True)
        m = eng.last_metrics
        for key in ("shared_pages", "cow_copies", "prefix_hits",
                    "prefix_hit_tokens", "free_pages", "preemptions"):
            assert key in m, key
        assert any(r["shared_pages"] > 0 for r in eng.metrics_log)


# ---------------------------------------------------------------------------
# Randomized scheduler stress: ~200-step fuzz vs the non-prefix oracle
# ---------------------------------------------------------------------------


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_scheduler_fuzz_token_exact_and_drained(seed):
    """~200 random scheduler events — admits with overlapping prefixes,
    n-sample forks, decode ticks, forced preemptions, natural completions —
    through a tight sharing pool, against a generously-provisioned
    non-prefix paged engine fed the identical submissions.  Greedy decoding
    makes outputs timing-independent, so every request must come back
    token-exact, and the sharing engine must end fully drained (all pages
    free, empty index, internal invariants intact)."""
    cfg = make_reduced(all_configs()["glm4-9b"])
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)

    pre_a = [7, 7, 3, 5, 1, 2, 9, 4]  # 2 full pages
    pre_b = [6, 6, 6, 6]  # 1 full page
    eng = ContinuousEngine(cfg, params, slots=3, capacity=24, paged=True,
                           page_size=4, n_pages=14, prefix_sharing=True)
    oracle = ContinuousEngine(cfg, params, slots=3, capacity=24, paged=True,
                              page_size=4, n_pages=0)  # auto: never preempts

    submitted = 0
    for _ in range(200):
        op = rng.choice(["submit", "submit", "fork", "step", "step", "step",
                         "preempt"])
        if op == "submit" and submitted < 14:
            pre = [pre_a, pre_b, []][int(rng.integers(0, 3))]
            tail = [int(t) for t in rng.integers(100, 400, size=int(rng.integers(1, 4)))]
            req = Request(prompt=pre + tail,
                          max_new_tokens=int(rng.integers(2, 6)))
            a, b = eng.submit(req), oracle.submit(req)
            assert a == b  # identical submission order => aligned request ids
            submitted += 1
        elif op == "fork" and submitted < 14:
            tail = [int(t) for t in rng.integers(100, 400, size=2)]
            req = Request(prompt=pre_a + tail,
                          max_new_tokens=int(rng.integers(2, 6)))
            n = int(rng.integers(2, 4))
            assert eng.submit_n(req, n) == oracle.submit_n(req, n)
            submitted += n
        elif op == "step":
            eng.step()
            oracle.step()
        elif op == "preempt":
            active = [i for i, s in enumerate(eng.slots) if s.active]
            if active:
                eng._preempt(int(rng.choice(active)))
        eng.pool.check()

    done = eng.run_until_done()
    done_o = oracle.run_until_done()
    assert set(done) == set(done_o) and len(done) == submitted
    for rid in done_o:
        assert done[rid].tokens == done_o[rid].tokens, rid
    assert eng.prefix_hits > 0  # the traffic really exercised sharing
    assert eng.pool.free_count == eng.n_pages, "pool must drain"
    assert len(eng.prefix) == 0, "index must drain with the pool"
    assert oracle.pool.free_count == oracle.n_pages
    eng.pool.check()
