"""Mixer-level tests: Mamba2 SSD chunked vs sequential oracle; RG-LRU
associative scan vs step recurrence; decode-state handoff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or a single-draw fallback shim

from repro.configs.base import LRUSpec, ModelConfig, SSMSpec
from repro.models.rglru import init_lru, init_lru_cache, lru_layer, lru_scan
from repro.models.ssm import (
    init_ssm,
    init_ssm_cache,
    ssd_chunked,
    ssd_reference,
    ssm_layer,
)


def _cfg(d=64):
    return ModelConfig(
        name="t", family="ssm", source="x", d_model=d, num_heads=4, num_kv_heads=4,
        head_dim=16, vocab_size=64, segments=(), param_dtype="float32", compute_dtype="float32",
    )


def _ssd_inputs(B=2, L=64, H=4, P=8, G=1, N=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, L, G, N)) * 0.5
    Cm = jax.random.normal(jax.random.fold_in(ks[3], 1), (B, L, G, N)) * 0.5
    return x, dt, A, Bm, Cm


class TestSSD:
    @pytest.mark.parametrize("chunk", [8, 16, 64])
    def test_chunked_matches_sequential(self, chunk):
        x, dt, A, Bm, Cm = _ssd_inputs()
        y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, chunk)
        y2, s2 = ssd_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4, rtol=1e-4)

    def test_nondivisible_length_pads(self):
        x, dt, A, Bm, Cm = _ssd_inputs(L=50)
        y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, 16)
        y2, s2 = ssd_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4, rtol=1e-4)

    def test_initial_state_carried(self):
        x, dt, A, Bm, Cm = _ssd_inputs(L=32)
        # run first half, then second half with carried state
        y_full, s_full = ssd_reference(x, dt, A, Bm, Cm)
        y1, s1 = ssd_chunked(x[:, :16], dt[:, :16], A, Bm[:, :16], Cm[:, :16], 8)
        y2, s2 = ssd_chunked(x[:, 16:], dt[:, 16:], A, Bm[:, 16:], Cm[:, 16:], 8, init_state=s1)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), atol=1e-4, rtol=1e-4
        )
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4, rtol=1e-4)

    def test_grouped_heads(self):
        x, dt, A, Bm, Cm = _ssd_inputs(H=8, G=2)
        y1, s1 = ssd_chunked(x, dt, A, Bm, Cm, 16)
        y2, s2 = ssd_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4, rtol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(L=st.integers(4, 48), chunk=st.sampled_from([4, 8, 16]), seed=st.integers(0, 50))
    def test_property_chunk_invariance(self, L, chunk, seed):
        x, dt, A, Bm, Cm = _ssd_inputs(L=L, seed=seed)
        y1, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk)
        y2, _ = ssd_reference(x, dt, A, Bm, Cm)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=2e-3)


class TestSSMLayer:
    def test_prefill_then_decode_equals_full(self):
        cfg = _cfg()
        spec = SSMSpec(d_inner=128, head_dim=16, state_dim=16, conv_dim=4, chunk=8)
        params = init_ssm(jax.random.PRNGKey(0), cfg, spec, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, 64)) * 0.5
        y_full, _ = ssm_layer(cfg, spec, params, x, mode="train")
        cache = init_ssm_cache(2, spec, jnp.float32)
        y1, cache = ssm_layer(cfg, spec, params, x[:, :16], cache=cache, mode="prefill")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y_full[:, :16]), atol=1e-3)
        for t in range(16, 20):
            yt, cache = ssm_layer(cfg, spec, params, x[:, t : t + 1], cache=cache, mode="decode")
            np.testing.assert_allclose(
                np.asarray(yt[:, 0]), np.asarray(y_full[:, t]), atol=1e-3, err_msg=f"t={t}"
            )


class TestLRU:
    def test_scan_matches_loop(self):
        B, L, W = 2, 32, 16
        a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(0), (B, L, W)))
        b = jax.random.normal(jax.random.PRNGKey(1), (B, L, W))
        hs = lru_scan(a, b)
        h = jnp.zeros((B, W))
        for t in range(L):
            h = a[:, t] * h + b[:, t]
            np.testing.assert_allclose(np.asarray(hs[:, t]), np.asarray(h), atol=1e-5)

    def test_prefill_then_decode_equals_full(self):
        cfg = _cfg(d=32)
        spec = LRUSpec(lru_width=32, conv_dim=4, num_heads=2)
        params = init_lru(jax.random.PRNGKey(0), cfg, spec, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, 32)) * 0.5
        y_full, _ = lru_layer(cfg, spec, params, x, mode="train")
        cache = init_lru_cache(2, spec, jnp.float32)
        y1, cache = lru_layer(cfg, spec, params, x[:, :16], cache=cache, mode="prefill")
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y_full[:, :16]), atol=1e-4)
        for t in range(16, 20):
            yt, cache = lru_layer(cfg, spec, params, x[:, t : t + 1], cache=cache, mode="decode")
            np.testing.assert_allclose(
                np.asarray(yt[:, 0]), np.asarray(y_full[:, t]), atol=1e-4, err_msg=f"t={t}"
            )

    def test_forget_gate_bounds(self):
        """a_t in (0,1): state remains bounded for bounded input."""
        B, L, W = 1, 256, 8
        a = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(0), (B, L, W)) + 2.0)
        b = jnp.ones((B, L, W))
        hs = lru_scan(a, b)
        assert np.isfinite(np.asarray(hs)).all()
